"""Continuous-batching engine: scheduler occupancy, slot recycling,
bucketed-jit stability, and token-for-token equivalence with the
lock-step serving loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.models.model import init_caches
from repro.optim.madam import MadamConfig
from repro.serving import (Engine, Request, RequestQueue, RequestState,
                           Scheduler, summarize)
from repro.serving.metrics import RequestMetrics
from repro.training import build_decode_step, init_train_state


# ---------------------------------------------------------------------------
# pure-python lifecycle pieces


def test_queue_orders_and_gates_by_arrival():
    q = RequestQueue([Request(rid=1, prompt=[1], max_new_tokens=1, arrival=2.0),
                      Request(rid=0, prompt=[1], max_new_tokens=1, arrival=0.5)])
    q.push(Request(rid=2, prompt=[1], max_new_tokens=1, arrival=1.0))
    assert q.pop_ready(0.0) is None          # nothing has arrived yet
    assert q.pop_ready(0.6).rid == 0
    assert q.pop_ready(3.0).rid == 2         # arrival order, not push order
    assert q.pop_ready(3.0).rid == 1
    assert not q


def test_queue_same_arrival_is_fifo():
    """Requests sharing an arrival time pop in push order (stable sort —
    ties must not reorder a burst)."""
    q = RequestQueue()
    for rid in (3, 1, 4, 1, 5):
        q.push(Request(rid=rid, prompt=[1], max_new_tokens=1, arrival=1.0))
    q.push(Request(rid=0, prompt=[1], max_new_tokens=1, arrival=0.5))
    assert q.pop_ready(2.0).rid == 0
    assert [q.pop_ready(2.0).rid for _ in range(5)] == [3, 1, 4, 1, 5]


def test_queue_requeue_restores_head():
    q = RequestQueue([Request(rid=0, prompt=[1], max_new_tokens=1),
                      Request(rid=1, prompt=[1], max_new_tokens=1)])
    head = q.pop_ready(0.0)
    q.requeue(head)                       # admission failed: back in front
    assert q.pop_ready(0.0).rid == 0
    assert q.pop_ready(0.0).rid == 1


def test_scheduler_reuses_freed_slot():
    s = Scheduler(2)
    a = s.admit(Request(rid=0, prompt=[1], max_new_tokens=4), now=0.0)
    b = s.admit(Request(rid=1, prompt=[1], max_new_tokens=4), now=0.0)
    assert {a.slot, b.slot} == {0, 1} and not s.has_free()
    s.release(a.slot)
    c = s.admit(Request(rid=2, prompt=[1], max_new_tokens=4), now=1.0)
    assert c.slot == a.slot                  # the freed row is recycled
    assert set(s.running) == {b.slot, c.slot}


def test_scheduler_lowest_slot_first_after_interleaved_releases():
    """Freed slots are reused lowest-first regardless of release order —
    admissions stay deterministic across interleavings."""
    s = Scheduler(4)
    states = [s.admit(Request(rid=i, prompt=[1], max_new_tokens=1), now=0.0)
              for i in range(4)]
    assert [rs.slot for rs in states] == [0, 1, 2, 3]
    s.release(2)
    s.release(0)
    s.release(3)
    order = [s.admit(Request(rid=10 + i, prompt=[1], max_new_tokens=1),
                     now=1.0).slot for i in range(3)]
    assert order == [0, 2, 3]


def test_eos_with_multi_codebook_tokens():
    """Codebook steps append lists; EOS fires only when every codebook
    emits it."""
    rs = RequestState(
        Request(rid=0, prompt=[[1, 1]], max_new_tokens=8, eos_id=7), slot=0,
        t_admit=0.0)
    rs.generated.append([7, 3])
    assert not rs.done
    rs.generated.append([7, 7])
    assert rs.done


def test_request_state_done_on_eos_and_budget():
    rs = RequestState(
        Request(rid=0, prompt=[1], max_new_tokens=3, eos_id=7), slot=0,
        t_admit=0.0)
    rs.generated += [1, 2]
    assert not rs.done
    rs.generated.append(7)
    assert rs.done                           # EOS before the budget
    rs2 = RequestState(
        Request(rid=1, prompt=[1], max_new_tokens=2), slot=0, t_admit=0.0)
    rs2.generated += [3, 4]
    assert rs2.done                          # budget exhausted


def test_metrics_aggregation():
    ms = [RequestMetrics(rid=i, slot=0, arrival=0.0, t_admit=0.1,
                         t_first_token=0.5, t_finish=1.0 + i,
                         prompt_len=4, new_tokens=10) for i in range(4)]
    agg = summarize(ms, wall=2.0)
    assert agg["completed"] == 4 and agg["generated_tokens"] == 40
    assert agg["tokens_per_s"] == pytest.approx(20.0)
    assert agg["ttft_mean_s"] == pytest.approx(0.5)
    assert ms[0].decode_tps == pytest.approx(9.0 / 0.5)


# ---------------------------------------------------------------------------
# engine over the real model (fp32 smoke config => deterministic tokens)


@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    return cfg, qcfg, mcfg, params


def _prompts(cfg, n, plen, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (n, plen), dtype=np.int32)


def _lockstep_tokens(cfg, qcfg, mcfg, params, prompts, gen_len, max_len):
    """The old one-shot serve loop: batch prefill through the decode path,
    then lock-step greedy decode."""
    B, P = prompts.shape
    decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    caches = init_caches(B, max_len, cfg)
    logits, caches = decode(params, caches, {"tokens": jnp.asarray(prompts)},
                            jnp.zeros((B,), jnp.int32))
    tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(B, 1)
    gen = [tok]
    for i in range(gen_len - 1):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, caches = decode(params, caches, {"tokens": tok}, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(B, 1)
        gen.append(tok)
    return np.asarray(jnp.concatenate(gen, axis=1))


def test_engine_matches_lockstep_token_for_token(serve_setup):
    cfg, qcfg, mcfg, params = serve_setup
    B, P, G, max_len = 3, 12, 6, 32
    prompts = _prompts(cfg, B, P)
    ref = _lockstep_tokens(cfg, qcfg, mcfg, params, prompts, G, max_len)

    eng = Engine(cfg, qcfg, mcfg, params, num_slots=B, max_len=max_len)
    eng.run([Request(rid=i, prompt=prompts[i].tolist(), max_new_tokens=G)
             for i in range(B)])
    got = np.stack([
        np.asarray(rs.generated, np.int32)
        for rs in sorted(eng.finished, key=lambda r: r.request.rid)])
    np.testing.assert_array_equal(got, ref)


def test_engine_admits_into_freed_slots_without_recompiling(serve_setup):
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (6 + 3 * i,),
                                        dtype=np.int32),
                    max_new_tokens=3 + i) for i in range(5)]
    eng.run(reqs)

    assert len(eng.finished) == 5
    assert all(len(rs.generated) == rs.request.max_new_tokens
               for rs in eng.finished)
    # 5 requests through 2 slots: later admissions reuse freed rows
    assert {rs.slot for rs in eng.finished} == {0, 1}
    first_finish = min(m.t_finish for m in eng.completed)
    assert max(m.t_admit for m in eng.completed) >= first_finish

    # the decode step compiled exactly once: admissions never retrace it,
    # and prefill shapes stay within the bucket set
    assert eng.decode_compiles == 1
    assert eng.prefill_compiles <= 2  # prompts 6..18 -> one or two buckets
    assert eng.decode_steps > 0 and eng.prefills == 5


def test_recycled_slot_reproduces_fresh_output(serve_setup):
    """A sequence decoded in a recycled cache row must match the same
    request served on a fresh engine — stale KV must not leak."""
    cfg, qcfg, mcfg, params = serve_setup
    prompt = _prompts(cfg, 1, 10, seed=3)[0]
    mk = lambda rid: Request(rid=rid, prompt=prompt.tolist(), max_new_tokens=5)

    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    eng.run([mk(0), mk(1)])  # second request lands in the recycled slot 0
    a, b = sorted(eng.finished, key=lambda r: r.request.rid)
    assert a.slot == b.slot == 0
    assert a.generated == b.generated


def test_step_with_explicit_clock_keeps_one_timebase(serve_setup):
    """Simulated-time replay: every timestamp a step produces must use the
    caller's clock, or TTFT/latency mix timebases."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=24)
    eng.submit(Request(rid=0, prompt=_prompts(cfg, 1, 8)[0].tolist(),
                       max_new_tokens=3, arrival=2.0))
    t = 0.0
    while not eng.completed:
        eng.step(now=t)
        t += 1.0
    m = eng.completed[0]
    assert m.t_admit == 2.0 and m.t_first_token == 2.0  # admission step
    # admission step also decodes (tokens 1+2 at t=2), third token at t=3
    assert m.t_finish == 3.0
    assert m.ttft == 0.0 and m.latency == 1.0


def test_oversized_prompt_rejected_before_slot_binding(serve_setup):
    """An over-capacity request must fail at submit(), not wedge a slot."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        eng.submit(Request(rid=0, prompt=list(range(40)), max_new_tokens=2))
    assert eng.scheduler.free_slots == 1 and not eng.queue
    # the engine is still fully serviceable afterwards
    eng.run([Request(rid=1, prompt=_prompts(cfg, 1, 8)[0].tolist(),
                     max_new_tokens=2)])
    assert len(eng.finished) == 1 and len(eng.finished[0].generated) == 2


def test_shape_mismatched_prompt_rejected_at_submit(serve_setup):
    """validate() checks prompt rank/row-width against the model, not
    just length — codebook rows into a flat-vocab model must be a
    submit-time ValueError (the gateway's 400), never a step() crash."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=16)
    bad = [[[1, 2], [3, 4]],      # codebook rows, flat-vocab model
           [[1, 2], [3]],         # ragged rows
           [1.5, 2.5],            # non-integer ids
           [-1, 2],               # negative id
           [1, cfg.vocab_size],   # id beyond the vocab (gather clamps!)
           []]                    # empty prompt
    for prompt in bad:
        with pytest.raises(ValueError):
            eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    assert eng.scheduler.free_slots == 1 and not eng.queue


def test_admission_failure_fails_only_offending_request(serve_setup):
    """A malformed request that slips past validate() (pushed straight
    into the queue) must error out alone: the engine keeps stepping and
    the co-submitted request completes normally."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)
    events = []
    eng.finish_sink = lambda rid, reason, rs: events.append((rid, reason))
    eng.submit(Request(rid=0, prompt=_prompts(cfg, 1, 8)[0].tolist(),
                       max_new_tokens=4))
    eng.queue.push(Request(rid=1, prompt=[[1, 2], [3]],  # bypass submit()
                           max_new_tokens=4))
    while eng.queue or eng.scheduler.running:
        eng.step()
    assert eng.admit_failures == 1
    assert (1, "error") in events and (0, "length") in events
    assert len(eng.finished) == 1
    assert len(eng.finished[0].generated) == 4
    assert eng.scheduler.free_slots == 2


def test_admission_failure_releases_page_reservation(serve_setup):
    """When _admit blows up after pages were reserved, the reservation
    must return to the pool and the slot must free — and the engine
    stays serviceable."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 page_size=4)
    baseline = eng.allocator.available
    events = []
    eng.finish_sink = lambda rid, reason, rs: events.append((rid, reason))
    real_prefill = eng._prefill_fn

    def boom(*a, **k):
        raise RuntimeError("prefill exploded")

    eng._prefill_fn = boom
    eng.submit(Request(rid=0, prompt=list(range(1, 9)), max_new_tokens=4))
    eng.step()
    assert eng.admit_failures == 1 and (0, "error") in events
    assert eng.allocator.available == baseline, "reservation leaked"
    assert eng.scheduler.free_slots == 2
    eng._prefill_fn = real_prefill
    eng.run([Request(rid=1, prompt=list(range(1, 9)), max_new_tokens=2)])
    assert len(eng.finished) == 1 and len(eng.finished[0].generated) == 2
    # a reservation-time failure (ragged prompt the chain hash can't
    # even convert) archives an "error" state too — slot never bound —
    # so offline callers' finished+aborted accounting still balances
    events.clear()
    eng.queue.push(Request(rid=2, prompt=[[1, 2], [3]], max_new_tokens=2))
    eng.step()
    assert (2, "error") in events
    assert eng.aborted and eng.aborted[-1].slot == -1
    assert eng.allocator.available == baseline


def test_persistent_admission_failure_trips_the_engine(serve_setup):
    """Per-request fault isolation must not mask a broken engine: once
    every admission fails ADMIT_FAIL_TRIP times in a row, step()
    re-raises so the driver dies and /health goes 503 (a load balancer
    can eject the node). A success in between resets the streak."""
    from repro.serving.engine import ADMIT_FAIL_TRIP
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)

    def boom(*a, **k):
        raise RuntimeError("prefill exploded")

    real_prefill, eng._prefill_fn = eng._prefill_fn, boom
    for i in range(ADMIT_FAIL_TRIP - 1):
        eng.queue.push(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=2))
    eng.step()  # one below the trip: all isolated, engine survives
    assert eng.admit_failures == ADMIT_FAIL_TRIP - 1
    eng._prefill_fn = real_prefill
    eng.run([Request(rid=100, prompt=[1, 2, 3], max_new_tokens=2)])
    assert eng._admit_fail_streak == 0  # success resets the streak
    eng._prefill_fn = boom
    for i in range(ADMIT_FAIL_TRIP):
        eng.queue.push(Request(rid=200 + i, prompt=[1, 2, 3],
                               max_new_tokens=2))
    with pytest.raises(RuntimeError, match="prefill exploded"):
        eng.step()
    assert eng.admit_failures == ADMIT_FAIL_TRIP * 2 - 1


def test_slot_fills_every_cache_position(serve_setup):
    """Capacity regression: a budget larger than the cache must truncate
    only after position max_len - 1 was written — the old boundary
    (``_slot_len + 1 >= max_len``) wasted the last position of every
    slot. With prompt P and capacity M that is M - P + 1 tokens (the
    final token is produced off position M - 1 and never cached)."""
    cfg, qcfg, mcfg, params = serve_setup
    P, M = 8, 16
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=M)
    eng.run([Request(rid=0, prompt=_prompts(cfg, 1, P)[0].tolist(),
                     max_new_tokens=100)])
    m = eng.completed[0]
    assert m.new_tokens == M - P + 1
    assert m.truncated
    # the in-graph cursor consumed every position
    assert eng._slot_len[0] == M


def test_truncated_flag_distinguishes_capacity_from_eos(serve_setup):
    """A capacity-truncated request must not report like a normal
    completion; budget/EOS completions stay untruncated."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16)
    prompts = _prompts(cfg, 2, 8, seed=9)
    agg = eng.run([
        Request(rid=0, prompt=prompts[0].tolist(), max_new_tokens=100),
        Request(rid=1, prompt=prompts[1].tolist(), max_new_tokens=3),
    ])
    by = {m.rid: m for m in eng.completed}
    assert by[0].truncated and not by[1].truncated
    assert agg["truncated"] == 1.0


def test_run_accounting_survives_drain(serve_setup):
    """drain_finished() clears the metrics archive; a later run() must
    still summarize exactly its own completions (run-local sink, not a
    slice of ``completed``)."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    agg1 = eng.run([Request(rid=0, prompt=_prompts(cfg, 1, 6)[0].tolist(),
                            max_new_tokens=2)])
    assert agg1["completed"] == 1
    drained = eng.drain_finished()
    assert [rs.request.rid for rs in drained] == [0]
    assert eng.completed == [] and eng.finished == []
    agg2 = eng.run([
        Request(rid=1, prompt=_prompts(cfg, 1, 6, seed=1)[0].tolist(),
                max_new_tokens=2),
        Request(rid=2, prompt=_prompts(cfg, 1, 6, seed=2)[0].tolist(),
                max_new_tokens=2)])
    assert agg2["completed"] == 2
    assert sorted(m.rid for m in eng.completed) == [1, 2]


def test_eos_on_first_token_releases_slot_at_admission(serve_setup):
    """A prompt whose first greedy token is EOS finishes inside the
    admission step: the slot frees immediately and the engine keeps
    serving the queue."""
    cfg, qcfg, mcfg, params = serve_setup
    prompt = _prompts(cfg, 1, 8, seed=7)[0].tolist()
    probe = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    probe.run([Request(rid=0, prompt=list(prompt), max_new_tokens=1)])
    first = probe.finished[0].generated[0]

    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=10,
                       eos_id=first))
    eng.submit(Request(rid=1, prompt=_prompts(cfg, 1, 8, seed=8)[0].tolist(),
                       max_new_tokens=2))
    t = 0.0
    while eng.queue or eng.scheduler.running:
        eng.step(now=t)
        t += 1.0
    by = {rs.request.rid: rs for rs in eng.finished}
    assert by[0].generated == [first]          # EOS at the admission step
    m0 = [m for m in eng.completed if m.rid == 0][0]
    assert m0.t_finish == m0.t_first_token == 0.0 and not m0.truncated
    assert len(by[1].generated) == 2           # queue kept moving
    assert eng.scheduler.free_slots == 1


def test_engine_interleaves_mixed_lengths(serve_setup):
    """Shorter requests finish and hand their slot to waiting ones while
    longer neighbours keep decoding (continuous batching, not drain)."""
    cfg, qcfg, mcfg, params = serve_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=64)
    prompts = _prompts(cfg, 3, 8, seed=5)
    eng.run([
        Request(rid=0, prompt=prompts[0].tolist(), max_new_tokens=12),
        Request(rid=1, prompt=prompts[1].tolist(), max_new_tokens=2),
        Request(rid=2, prompt=prompts[2].tolist(), max_new_tokens=2),
    ])
    by_rid = {m.rid: m for m in eng.completed}
    # rid=2 was admitted into rid=1's freed slot while rid=0 still decoded
    assert by_rid[2].t_admit >= by_rid[1].t_finish
    assert by_rid[2].slot == by_rid[1].slot
    assert by_rid[0].t_finish >= by_rid[2].t_admit
