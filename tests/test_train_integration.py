"""End-to-end integration: LNS-Madam training reduces loss; prefill/decode
serving path; roofline HLO parsing; dry-run machinery on a host mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.quantizer import QuantConfig
from repro.launch.roofline import collective_bytes, model_flops
from repro.optim.madam import MadamConfig
from repro.training import (build_decode_step, build_prefill_step,
                            build_train_step, init_train_state)
from repro.training.data import SyntheticLM

# multi-step jit'd training runs; CI's per-push job skips these (nightly full)
pytestmark = pytest.mark.slow


def _run_training(cfg, qcfg, steps=30, lr=2.0 ** -5, seed=0):
    mcfg = MadamConfig(lr=lr)
    state = init_train_state(jax.random.PRNGKey(seed), cfg, mcfg)
    step = jax.jit(build_train_step(cfg, qcfg, mcfg))
    data = SyntheticLM(cfg, batch=16, seq=32, seed=seed, noise_levels=4)
    losses = []
    for i, b in zip(range(steps), data):
        state, m = step(state, jax.tree.map(jnp.asarray, b))
        losses.append(float(m["loss"]))
    return losses


def test_lns_madam_training_reduces_loss():
    cfg = get_smoke_config("granite-8b")
    losses = _run_training(cfg, QuantConfig.lns_madam(), steps=60)
    assert losses[-1] < losses[0] - 0.3
    assert all(np.isfinite(l) for l in losses)


def test_lns_tracks_fp_training():
    """Paper Table 4 trend: 8-bit LNS-Madam ends close to the fp path."""
    cfg = get_smoke_config("granite-8b")
    lns = _run_training(cfg, QuantConfig.lns_madam(), steps=50)
    fp = _run_training(cfg, QuantConfig.full_precision(), steps=50)
    assert lns[-1] < fp[-1] + 0.35


def test_microbatch_accumulation_consistent():
    """accum_steps=2 computes (approximately) the same update as accum=1."""
    cfg = get_smoke_config("smollm-135m")
    mcfg = MadamConfig()
    qcfg = QuantConfig.lns_madam()
    state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
    data = SyntheticLM(cfg, batch=8, seq=16, seed=0)
    b = jax.tree.map(jnp.asarray, data.batch_at(0))
    s1, m1 = jax.jit(build_train_step(cfg, qcfg, mcfg, accum_steps=1))(state, b)
    s2, m2 = jax.jit(build_train_step(cfg, qcfg, mcfg, accum_steps=2))(state, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=0.05)
    c1 = jax.tree.leaves(s1.params)[1]
    c2 = jax.tree.leaves(s2.params)[1]
    assert np.mean(np.asarray(c1) == np.asarray(c2)) > 0.9


def test_prefill_then_decode_serving():
    cfg = get_smoke_config("gemma3-12b")
    mcfg = MadamConfig()
    state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
    qcfg = QuantConfig.lns_madam()
    prefill = jax.jit(build_prefill_step(cfg, qcfg, mcfg))
    decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    logits_p = prefill(state.params, {"tokens": toks})
    from repro.models import init_caches
    caches = init_caches(2, 24, cfg)
    logits_d, caches = decode(state.params, caches, {"tokens": toks},
                              jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=5e-2, atol=5e-2)


def test_collective_bytes_parser():
    hlo = """
  %all-reduce.1 = f32[256,1024]{1,0} all-reduce(f32[256,1024]{1,0} %add.3), replica_groups={}
  %ag = bf16[32,4096]{1,0} all-gather(bf16[32,2048]{1,0} %p0), dimensions={1}
  %ag.done = bf16[8,8]{1,0} all-gather-done(%x)
  %rs = f32[16]{0} reduce-scatter(f32[256]{0} %y), dimensions={0}
  %cp = u8[128]{0} collective-permute(u8[128]{0} %z)
  %noise = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    st = collective_bytes(hlo)
    assert st.bytes_by_kind["all-reduce"] == 256 * 1024 * 4
    assert st.bytes_by_kind["all-gather"] == 32 * 2048 * 2  # operand, not out
    assert st.bytes_by_kind["reduce-scatter"] == 256 * 4
    assert st.bytes_by_kind["collective-permute"] == 128
    assert st.count_by_kind["all-gather"] == 1  # -done not double counted


def test_model_flops_accounting():
    from repro.configs import SHAPES, get_config
    cfg = get_config("smollm-135m")
    mf_train = model_flops(cfg, SHAPES["train_4k"], "train")
    assert mf_train == pytest.approx(6 * cfg.params_count() * 256 * 4096)
    mf_dec = model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert mf_dec == pytest.approx(2 * cfg.params_count() * 128)
    moe = get_config("kimi-k2-1t-a32b")
    mf_moe = model_flops(moe, SHAPES["train_4k"], "train")
    assert mf_moe == pytest.approx(
        6 * moe.active_params_count() * 256 * 4096)


def test_host_mesh_sharded_train_step():
    """The full sharded train step runs on a real (1,1) host mesh."""
    from repro.distributed.sharding import shard_ctx
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke_config("qwen2.5-32b")
    mcfg = MadamConfig()
    mesh = make_host_mesh()
    with shard_ctx(mesh):
        state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
        step = jax.jit(build_train_step(cfg, QuantConfig.lns_madam(), mcfg))
        data = SyntheticLM(cfg, batch=4, seq=16, seed=0)
        b = jax.tree.map(jnp.asarray, data.batch_at(0))
        state, m = step(state, b)
        assert np.isfinite(float(m["loss"]))
